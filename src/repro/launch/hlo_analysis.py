"""Post-compile HLO analysis: collective-byte accounting + roofline.

cost_analysis() gives per-device HLO FLOPs / bytes-accessed; collective
traffic is NOT in cost_analysis, so we parse the optimized per-device
HLO text and sum the tensor sizes of every collective op.

Convention (documented in EXPERIMENTS.md): sizes are the collective's
OUTPUT tensor bytes per device; all-reduce counts x2 (ring
reduce-scatter + all-gather).  The (N-1)/N ring factor is folded into
~1.  The resulting ``collective_bytes`` is per-device traffic, so

    collective_s = collective_bytes / ICI_BW          (per chip)
    compute_s    = flops_per_device / PEAK_FLOPS      (per chip)
    memory_s     = bytes_per_device / HBM_BW          (per chip)

which matches the assignment formulas after multiplying numerator and
denominator by the chip count.
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<types>\(?[^)=]*?\)?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVES) + r")(?P<suffix>-start|-done)?\(",
)


def _shape_bytes(types: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(types):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective type: {'bytes': ..., 'count': ...} from optimized
    per-device HLO."""
    out = {c: {"bytes": 0, "count": 0} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if m.group("suffix") == "-done":
            continue     # async pair: count the -start only
        size = _shape_bytes(m.group("types"))
        mult = 2 if op == "all-reduce" else 1
        out[op]["bytes"] += size * mult
        out[op]["count"] += 1
    return out


def total_collective_bytes(per_type: Dict[str, Dict[str, float]]) -> int:
    return int(sum(v["bytes"] for v in per_type.values()))


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, *, peak_flops: float, hbm_bw: float,
             ici_bw: float) -> Dict[str, float]:
    compute_s = flops_per_dev / peak_flops
    memory_s = bytes_per_dev / hbm_bw
    collective_s = coll_bytes_per_dev / ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {**terms, "dominant": dominant,
            "step_time_lower_bound_s": bound,
            # fraction of the step the compute roofline would occupy if
            # the dominant term were fully overlapped-free:
            "roofline_fraction": compute_s / bound if bound > 0 else 0.0}
