import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Two artifacts per cell:

1. FULL compile (the dry-run gate): the production-depth step function
   must lower+compile on the 16x16 single-pod mesh and the 2x16x16
   multi-pod mesh.  Yields memory_analysis + the collective schedule.

2. Differential probes (single-pod roofline): XLA cost_analysis counts
   while-loop bodies ONCE, so scanned layers/microbatches/attention
   blocks are undercounted.  We therefore compile reduced-depth,
   reduced-batch variants (inner loops unrolled) and solve the
   per-device linear cost model

       f(bodies b, B_local, micros M) =
           opt(b) + M*g(b) + B_local*(e + b*c)

   with opt(b) = o0 + b*o1 (once per step: optimizer, grad init),
   g(b) = g0 + b*g1 (once per MICROBATCH, batch-independent: FSDP
   weight all-gathers — g ~ 0 when XLA hoists them out of the loop),
   and e + b*c per local batch row (fwd+bwd compute/activations).
   Train cells use 6 probes ((b,B) in PROBE_BODIES x {1,2} at M=1,
   plus two M=2 points); serve cells use the 4-point M=1 model.  The
   probe depths are {2,3} bodies, NOT {1,2}: a single-body graph
   compiles to a qualitatively different schedule (whole-graph fusion,
   different all-gather placement), which poisons the linear fit —
   both probe points must sit in the multi-layer regime.  Every number
   still derives from a compiled artifact (assignment: cost_analysis +
   as_text); tests/test_roofline.py validates the model against a fully
   unrolled small config.

Usage:
    python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import json
import subprocess
import sys
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")
METRICS = ("flops", "bytes", "coll")


def _build_jitted(cfg, shape, rules, n_micro, attn_impl="blockwise",
                  param_dtype=None, remat_policy="dots"):
    import jax

    from ..runtime import specs as SP
    from ..runtime.steps import (TrainHParams, build_decode_step,
                                 build_prefill_step, build_train_step)

    if shape.kind == "train":
        hp = TrainHParams(n_micro=n_micro, attn_impl=attn_impl,
                          remat_policy=remat_policy)
        step = build_train_step(cfg, hp)
        args, in_sh, out_sh = SP.train_cell(cfg, shape, rules)
        return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=(0, 1)), args
    if shape.kind == "prefill":
        step = build_prefill_step(cfg, max_seq=shape.seq_len,
                                  attn_impl=attn_impl)
        args, in_sh, out_sh = SP.prefill_cell(cfg, shape, rules,
                                              param_dtype)
        return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh), args
    step = build_decode_step(cfg)
    args, in_sh, out_sh = SP.decode_cell(cfg, shape, rules, param_dtype)
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(1,)), args


def _compile_and_measure(cfg, shape, rules, mesh, n_micro,
                         attn_impl="blockwise", param_dtype=None,
                         remat_policy="dots"):
    from .hlo_analysis import collective_bytes, total_collective_bytes

    jitted, args = _build_jitted(cfg, shape, rules, n_micro, attn_impl,
                                 param_dtype, remat_policy)
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older jax: one dict per program
        cost = cost[0] if cost else {}
    per_coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(total_collective_bytes(per_coll)),
        "per_coll": per_coll,
        "compiled": compiled,
        "wall_s": time.time() - t0,
    }


def _reduced(cfg, k):
    """Config with k scan bodies (and k encoder layers for enc-dec)."""
    kw = {"n_layers": k * cfg.scan_period}
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = k
    return cfg.replace(**kw)


#: probe depths for the differential solve — both in the multi-layer
#: regime (see the module docstring for why b=1 is excluded)
PROBE_BODIES = (2, 3)


def solve_probe_model(pts, metric):
    """Fit f(b, B, M) = opt(b) + M*g(b) + B*(e + b*c) to the probe
    compiles in ``pts`` (keyed ``(bodies, B_local, M)``), for one
    metric.  Returns the coefficient dict {o0, o1, g0, g1, e, c}."""
    b1, b2 = PROBE_BODIES
    db = b2 - b1
    f11, f21 = pts[(b1, 1, 1)][metric], pts[(b2, 1, 1)][metric]
    f12, f22 = pts[(b1, 2, 1)][metric], pts[(b2, 2, 1)][metric]
    c = (f22 - f21 - f12 + f11) / db
    e = f12 - f11 - b1 * c
    a1 = (f21 - f11) / db - c       # = o1 + g1 (one micro at M=1)
    a0 = f11 - b1 * a1 - e - b1 * c  # = o0 + g0
    g0 = g1 = 0.0
    if (b1, 2, 2) in pts:
        gb1 = pts[(b1, 2, 2)][metric] - f12     # g(b1) = g0 + b1*g1
        gb2 = pts[(b2, 2, 2)][metric] - f22     # g(b2) = g0 + b2*g1
        g1 = (gb2 - gb1) / db
        g0 = gb1 - b1 * g1
    return {"o0": a0 - g0, "o1": a1 - g1, "g0": g0, "g1": g1,
            "e": e, "c": c}


def predict_probe_model(coeffs, bodies, b_local, n_micro=1):
    """Evaluate the fitted per-device cost model at production depth."""
    return (coeffs["o0"] + bodies * coeffs["o1"]
            + n_micro * (coeffs["g0"] + bodies * coeffs["g1"])
            + b_local * (coeffs["e"] + bodies * coeffs["c"]))


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides_json: str = "", tag: str = "",
             probes: bool = True, attn_impl: str = "blockwise",
             n_micro: int = 0, serve_dtype: str = "",
             cfg_overrides: str = "", remat_policy: str = "dots") -> dict:
    import jax

    from .. import configs as C
    from ..models import layers as ML
    from ..models import ssd as MS
    from ..models import transformer as T
    from ..models.config import SHAPES, shape_applicable
    from ..runtime import specs as SP
    from ..runtime.sharding import use_rules
    from . import mesh as M
    from .hlo_analysis import roofline

    cfg = C.get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**json.loads(cfg_overrides))
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "tag": tag, "status": "skip", "reason": reason}
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}"
                      + (f"__{tag}" if tag else "") + ".json")
    if not ok:
        with open(fn, "w") as fh:
            json.dump(result, fh, indent=1)
        return result

    mesh = M.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    overrides = json.loads(overrides_json) if overrides_json else None
    rules = SP.cell_rules(cfg, shape, mesh, overrides)
    dp = SP._axis_size(mesh, rules.rules["batch"])
    n_micro_full = max(1, shape.global_batch // max(dp, 1)) \
        if shape.kind == "train" else 1
    if n_micro:
        n_micro_full = n_micro
    param_dtype = None
    if serve_dtype:
        import jax.numpy as jnp
        param_dtype = {"bf16": jnp.bfloat16, "f32": jnp.float32}[serve_dtype]
    n_bodies = cfg.n_bodies

    # ---------------------------------------------------- 1. full compile
    with use_rules(rules):
        full = _compile_and_measure(cfg, shape, rules, mesh, n_micro_full,
                                    attn_impl, param_dtype, remat_policy)
    compiled = full.pop("compiled")
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:  # noqa: BLE001
        mem_info = {}

    result.update({
        "status": "ok", "n_devices": n_dev, "dp": dp,
        "n_micro": n_micro_full, "n_bodies": n_bodies,
        "compile_wall_s": round(full["wall_s"], 1),
        "raw": {k: full[k] for k in METRICS},
        "collectives_full": full["per_coll"],
        "memory": mem_info,
    })

    # ------------------------------------------------ 2. roofline probes
    if probes:
        import dataclasses

        ML.UNROLL_BLOCKS = True
        MS.UNROLL_CHUNKS = True
        T.UNROLL_LAYERS = True
        # per-device local batch of the production cell
        b_loc_full = max(1, shape.global_batch // max(dp, 1))
        try:
            pts = {}
            for k in PROBE_BODIES:    # bodies
                for bl in (1, 2):     # local batch rows per device
                    pshape = dataclasses.replace(
                        shape, global_batch=max(dp, 1) * bl)
                    with use_rules(rules):
                        pts[(k, bl, 1)] = _compile_and_measure(
                            _reduced(cfg, k), pshape, rules, mesh, 1,
                            attn_impl, param_dtype, remat_policy)
            if shape.kind == "train" and n_micro_full > 1:
                pshape = dataclasses.replace(shape,
                                             global_batch=max(dp, 1) * 2)
                for k in PROBE_BODIES:  # measure the per-micro term g(b)
                    with use_rules(rules):
                        pts[(k, 2, 2)] = _compile_and_measure(
                            _reduced(cfg, k), pshape, rules, mesh, 2,
                            attn_impl, param_dtype, remat_policy)
        finally:
            ML.UNROLL_BLOCKS = False
            MS.UNROLL_CHUNKS = False
            T.UNROLL_LAYERS = False

        corrected = {}
        coeffs = {}
        for m in METRICS:
            coeffs[m] = solve_probe_model(pts, m)
            corrected[m] = predict_probe_model(coeffs[m], n_bodies,
                                               b_loc_full, n_micro_full)
        result["probe_walls_s"] = {str(k): round(v["wall_s"], 1)
                                   for k, v in pts.items()}
        result["probe_coeffs"] = coeffs
        result["corrected"] = corrected
        flops, bytes_, coll = (corrected[m] for m in METRICS)
    else:
        flops, bytes_, coll = (full[m] for m in METRICS)

    # useful-model-FLOPs accounting (per step, global)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_tok = T.model_flops_per_token(cfg)           # 6·N_active
    if shape.kind != "train":
        per_tok /= 3.0                                # 2·N_active (no bwd)
    model_flops = per_tok * tokens

    rf = roofline(flops, bytes_, coll, peak_flops=M.PEAK_FLOPS_BF16,
                  hbm_bw=M.HBM_BW, ici_bw=M.ICI_BW)
    result.update({
        "flops_per_device": flops, "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll,
        "model_flops_global": model_flops,
        "hlo_flops_global": flops * n_dev,
        "model_flops_ratio": (model_flops / (flops * n_dev)
                              if flops else None),
        **rf,
    })
    with open(fn, "w") as fh:
        json.dump(result, fh, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--attn-impl", default="blockwise")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACTS))
    ap.add_argument("--overrides", default="",
                    help="JSON dict of logical-rule overrides (perf exps)")
    ap.add_argument("--tag", default="", help="artifact suffix for perf exps")
    ap.add_argument("--n-micro", type=int, default=0,
                    help="override microbatch count (train cells)")
    ap.add_argument("--serve-dtype", default="",
                    help="param dtype for serve cells (bf16|f32)")
    ap.add_argument("--cfg-overrides", default="",
                    help="JSON dict applied via ModelConfig.replace")
    ap.add_argument("--remat-policy", default="dots",
                    choices=["dots", "none", "everything"])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        from .. import configs as C
        from ..models.config import SHAPES
        failures = []
        for arch in C.list_archs():
            for shape in SHAPES:
                for mesh_kind in meshes:
                    fn = os.path.join(args.out,
                                      f"{arch}__{shape}__{mesh_kind}.json")
                    if args.skip_existing and os.path.exists(fn):
                        print(f"[skip] {arch} {shape} {mesh_kind}",
                              flush=True)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--mesh", mesh_kind, "--out", args.out]
                    if mesh_kind == "multi" or args.no_probes:
                        cmd.append("--no-probes")  # roofline is single-pod
                    t0 = time.time()
                    print(f"[run ] {arch} {shape} {mesh_kind}", flush=True)
                    rc = subprocess.call(cmd, stdout=subprocess.DEVNULL)
                    print(f"       rc={rc} {time.time()-t0:.0f}s", flush=True)
                    if rc != 0:
                        failures.append((arch, shape, mesh_kind))
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    res = run_cell(args.arch, args.shape, meshes[0], args.out,
                   overrides_json=args.overrides, tag=args.tag,
                   probes=not args.no_probes, attn_impl=args.attn_impl,
                   n_micro=args.n_micro, serve_dtype=args.serve_dtype,
                   cfg_overrides=args.cfg_overrides,
                   remat_policy=args.remat_policy)
    if res.get("status") == "skip":
        print(f"SKIP {args.arch} {args.shape}: {res['reason']}")
        return 0
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives_full", "memory", "raw")},
                     indent=1))
    print("memory:", res.get("memory"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
