"""Mamba2 SSD (state-space duality) block — TPU-native chunked form.

The sequence is split into chunks of Q tokens.  Within a chunk the
computation is a masked, decay-weighted attention-like matmul
(MXU-friendly); across chunks a first-order recurrence over the running
state (B, H, P, N) is evaluated with ``lax.scan``.  This is the Mamba2
paper's algorithm; Jamba's Mamba-1 layers are instantiated with the same
block (d_state from config) — see DESIGN.md §Hardware-adaptation.

Shapes: D = d_model, I = d_inner, H = ssm heads, P = head dim,
G = groups, N = d_state, K = conv kernel width, Q = chunk.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..runtime.sharding import lshard
from .config import ModelConfig
from .layers import rms_norm_gated

# Dry-run probe hook (see layers.UNROLL_BLOCKS): unroll the chunk scan so
# cost_analysis counts every chunk.  Above UNROLL_CHUNKS_MAX chunks the
# scan stays rolled: compile time would explode while the intra-chunk
# matmuls the loop hides are only ~4-8% of an SSM layer's FLOPs (the
# in/out projections dominate — that is the point of SSD's linear cost);
# the residual undercount is documented in EXPERIMENTS.md §Methodology.
UNROLL_CHUNKS = False
UNROLL_CHUNKS_MAX = 64


def ssd_params_layout(cfg: ModelConfig):
    D, I, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    G, N, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    d_in = 2 * I + 2 * G * N + H
    conv_dim = cfg.conv_dim
    return {
        "w_in": ((D, d_in), ("embed", "ssm_inner"), D ** -0.5),
        "conv_w": ((conv_dim, K), ("ssm_inner", "conv"), conv_dim ** -0.5),
        "conv_b": ((conv_dim,), ("ssm_inner",), 0.0),
        "dt_bias": ((H,), ("ssm_heads",), 0.0),
        "A_log": ((H,), ("ssm_heads",), 0.0),
        "skip_D": ((H,), ("ssm_heads",), 0.0),
        "w_norm": ((I,), ("ssm_inner",), 0.0),
        "w_out": ((I, D), ("ssm_inner", "embed"), I ** -0.5),
    }


def _split_in(h, cfg: ModelConfig):
    I, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xc, Bm, Cm, dt = jnp.split(
        h, [I, 2 * I, 2 * I + G * N, 2 * I + 2 * G * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(x, w, b, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B,S,C); w: (C,K); cache: (B,K-1,C)
    holds the trailing inputs of the previous segment.  Returns
    (y (B,S,C), new_cache (B,K-1,C))."""
    K = w.shape[1]
    if cache is None:
        cache = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([cache, x], axis=1)               # (B, S+K-1, C)
    # K is tiny (4): express the conv as K shifted multiply-adds
    y = sum(xx[:, i:i + x.shape[1], :] * w[:, i][None, None, :]
            for i in range(K))
    y = y + b[None, None, :]
    new_cache = xx[:, -(K - 1):, :] if K > 1 else cache
    return y, new_cache


def ssd_scan(xh, dt, A, Bm, Cm, chunk: int,
             init_state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.  xh: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm, Cm: (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, S)
    S_in = S
    pad = (-S) % Q
    if pad:  # padded tail has dt=0 => zero contribution to the state
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),) * (dt.ndim - 2))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q

    a = dt * A[None, None, :]                               # (B,S,H) <= 0
    # chunk views, scan over the chunk axis
    ach = a.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)      # (nc,B,Q,H)
    xch = xh.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtch = dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    Bch = Bm.reshape(B, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    Cch = Cm.reshape(B, nc, Q, G, N).transpose(1, 0, 2, 3, 4)

    causal = jnp.tril(jnp.ones((Q, Q), jnp.bool_))

    def chunk_step(state, inp):
        a_c, x_c, dt_c, B_c, C_c = inp                      # leading dim B
        cum = jnp.cumsum(a_c, axis=1)                       # (B,Q,H)
        # intra-chunk (attention-like, per head through its group)
        CB = jnp.einsum("bqgn,bkgn->bgqk", C_c.astype(jnp.float32),
                        B_c.astype(jnp.float32))            # (B,G,Q,Q)
        Ldec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,K,H)
        Ldec = jnp.where(causal[None, :, :, None], Ldec, 0.0)
        CBh = jnp.repeat(CB, hpg, axis=1)                   # (B,H,Q,K)
        scores = CBh.transpose(0, 2, 3, 1) * Ldec * dt_c[:, None, :, :]
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", scores,
                            x_c.astype(jnp.float32))
        # inter-chunk: contribution of the carried state (group-aware)
        state_g = state.reshape(B, G, hpg, P, N)
        y_off = jnp.einsum("bqgn,bghpn->bqghp", C_c.astype(jnp.float32),
                           state_g).reshape(B, Q, H, P)
        y_off = y_off * jnp.exp(cum)[..., None]
        # new chunk state
        decay_tail = jnp.exp(cum[:, -1:, :] - cum)          # (B,Q,H)
        sB = jnp.repeat(B_c, hpg, axis=2)                   # (B,Q,H,N)
        contrib = jnp.einsum("bqhn,bqhp->bhpn",
                             (sB * (dt_c * decay_tail)[..., None]
                              ).astype(jnp.float32),
                             x_c.astype(jnp.float32))
        state_new = state * jnp.exp(jnp.sum(a_c, axis=1))[..., None, None] \
            + contrib
        return state_new, (y_diag + y_off).astype(xh.dtype)

    state0 = init_state if init_state is not None else \
        jnp.zeros((B, H, P, N), jnp.float32)
    final, ych = lax.scan(
        chunk_step, state0, (ach, xch, dtch, Bch, Cch),
        unroll=nc if (UNROLL_CHUNKS and nc <= UNROLL_CHUNKS_MAX) else 1)
    y = ych.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y[:, :S_in], final


def ssd_layer(p, x, cfg: ModelConfig, cache: Optional[dict] = None,
              return_cache: bool = False):
    """Full-sequence SSD block: (B,S,D) -> (B,S,D).

    With ``return_cache`` also returns {"conv": (B,K-1,conv_dim),
    "state": (B,H,P,N)} for subsequent decode."""
    B, S, D = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    h = x @ p["w_in"].astype(x.dtype)
    z, xc, Bm, Cm, dt = _split_in(h, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_tail = _causal_conv(
        conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype),
        None if cache is None else cache.get("conv"))
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B, S, G, N)
    Cm = conv_out[..., cfg.d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(B, S, H, P)
    xh = lshard(xh, "batch", "seq", "ssm_heads", None)
    y, state = ssd_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                        None if cache is None else cache.get("state"))
    y = y + xh.astype(jnp.float32).astype(y.dtype) * \
        p["skip_D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm_gated(y, z, p["w_norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    if return_cache:
        return out, {"conv": conv_tail, "state": state}
    return out


def ssd_decode(p, x, cache: dict, cfg: ModelConfig):
    """Single-token decode: x (B,1,D); cache {"conv": (B,K-1,conv_dim),
    "state": (B,H,P,N)}.  Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    hpg = H // G
    h = x @ p["w_in"].astype(x.dtype)                       # (B,1,d_in)
    z, xc, Bm, Cm, dt = _split_in(h, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)        # (B,1,conv_dim)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,K,c)
    w = p["conv_w"].astype(x.dtype)                         # (c,K)
    conv_out = jnp.einsum("bkc,ck->bc", window, w) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]            # (B,1,c)
    new_conv = window[:, 1:, :]
    xc = conv_out[..., :cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B, G, N)
    Cm = conv_out[..., cfg.d_inner + G * N:].reshape(B, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(B, H, P)
    decay = jnp.exp(dt * A[None, :])                        # (B,H)
    Bh = jnp.repeat(Bm, hpg, axis=1)                        # (B,H,N)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh.astype(jnp.float32),
                   xh.astype(jnp.float32))
    Ch = jnp.repeat(Cm, hpg, axis=1)
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), state)
    y = y + xh.astype(jnp.float32) * p["skip_D"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm_gated(y, z, p["w_norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    return out, {"conv": new_conv, "state": state}
