"""Model building blocks, pure JAX (jnp + lax), shard-annotated.

Everything takes explicit param pytrees; no framework magic.  Attention
has three interchangeable implementations (exact same math):

- ``naive``     — materializes (…, S, T) scores; CPU unit tests, decode.
- ``blockwise`` — double-scan flash-style streaming over KV blocks with a
                  running log-sum-exp; the memory-footprint shape the
                  Pallas kernel mirrors; used for long-sequence lowering.
- ``pallas``    — the TPU kernel in repro.kernels (TARGET hardware).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..runtime.sharding import axis_size, lshard
from .config import ModelConfig

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 1024

# Dry-run probe hook: XLA cost_analysis counts while-loop bodies once, so
# the differential-compile probes unroll the streaming-attention loops to
# obtain loop-exact FLOP/collective counts (launch/dryrun.py sets this).
UNROLL_BLOCKS = False


# --------------------------------------------------------------------- norms
def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rms_norm_gated(x, z, w, eps: float = 1e-6):
    """Mamba2 output norm: RMSNorm(x * silu(z))."""
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return rms_norm(x, w, eps)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) *
                  (-math.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ----------------------------------------------------------------- attention
def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """Additive mask bias (..., Sq, Sk) from position vectors."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]        # q - k
    ok = jnp.ones(diff.shape, jnp.bool_)
    if causal:
        ok &= diff >= 0
    if window:
        ok &= diff < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def attention_core_naive(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                         cap=0.0, scale=None):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D); GQA by head grouping.
    Returns (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale or D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    bias = _mask_bias(q_pos, k_pos, causal, window)         # (B,Sq,Sk) or (Sq,Sk)
    while bias.ndim < scores.ndim:
        bias = bias[:, None] if bias.ndim >= 3 else bias[None]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_core_blockwise(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                             cap=0.0, scale=None,
                             block_q=DEFAULT_BLOCK_Q,
                             block_k=DEFAULT_BLOCK_K,
                             skip_blocks=False):
    """Flash-style streaming attention (same signature/semantics as naive).

    Outer scan over q blocks, inner scan over kv blocks with running
    (max, denom, acc).  With ``skip_blocks`` the inner loop is unrolled
    per q block and statically skips fully-masked causal blocks (used by
    the perf-optimized configs; identical numerics)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale or D ** -0.5
    if UNROLL_BLOCKS:
        # probe mode: keep the unrolled grid small; FLOPs are invariant
        # to the block size, which is all the probes measure.
        block_q = max(block_q, -(-Sq // 8))
        block_k = max(block_k, -(-Sk // 8))
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    # pad to block multiples
    pq, pk = nq * block_q - Sq, nk * block_k - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pk)),
                        constant_values=jnp.iinfo(jnp.int32).max)

    qb = q.reshape(B, nq, block_q, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(B, nq, block_q).transpose(1, 0, 2)
    kb = k.reshape(B, nk, block_k, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, block_k, KV, D).transpose(1, 0, 2, 3, 4)
    kpb = k_pos.reshape(B, nk, block_k).transpose(1, 0, 2)

    def q_block(qi, qp):
        """qi: (B, bq, KV, G, D); returns (B, bq, KV, G, D)."""
        def kv_step(carry, inp):
            acc, m, l = carry
            ki, vi, kp = inp
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            s = softcap(s, cap)
            bias = _mask_bias(qp, kp, causal, window)       # (B, bq, bk)
            s = s + bias[:, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vi.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, G, block_q, D), jnp.float32)
        m0 = jnp.full((B, KV, G, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        carry, _ = lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kpb),
                            unroll=nk if UNROLL_BLOCKS else 1)
        acc, m, l = carry
        l = jnp.where(l == 0.0, 1.0, l)
        out = acc / l[..., None]
        return out.transpose(0, 3, 1, 2, 4)                 # (B,bq,KV,G,D)

    _, out = lax.scan(lambda c, t: (c, q_block(t[0], t[1])), None,
                      (qb, qpb), unroll=nq if UNROLL_BLOCKS else 1)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * block_q, H, D)
    return out[:, :Sq].astype(q.dtype)


def attention_core(q, k, v, q_pos, k_pos, impl="naive", **kw):
    if impl == "blockwise":
        return attention_core_blockwise(q, k, v, q_pos, k_pos, **kw)
    if impl == "pallas":
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos, **kw)
    kw.pop("block_q", None), kw.pop("block_k", None), kw.pop("skip_blocks", None)
    return attention_core_naive(q, k, v, q_pos, k_pos, **kw)


# ------------------------------------------------------------ attention layer
def attn_params_layout(cfg: ModelConfig, cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    lay = {
        "wq": ((D, H * hd), ("embed", "qkv"), D ** -0.5),
        "wk": ((D, KV * hd), ("embed", "qkv"), D ** -0.5),
        "wv": ((D, KV * hd), ("embed", "qkv"), D ** -0.5),
        "wo": ((H * hd, D), ("qkv", "embed"), (H * hd) ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        lay.update({"bq": ((H * hd,), ("qkv",), 0.0),
                    "bk": ((KV * hd,), ("qkv",), 0.0),
                    "bv": ((KV * hd,), ("qkv",), 0.0)})
    return lay


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _proj_qkv(p, x, cfg: ModelConfig, rope: bool, positions):
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q, k, v = (q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype),
                   v + p["bv"].astype(x.dtype))
    q, k, v = _split_heads(q, H, hd), _split_heads(k, KV, hd), _split_heads(v, KV, hd)
    if rope and cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def pad_heads_for_tp(q, k, v):
    """Pad heads so the q-head count divides the tensor-parallel extent,
    preserving the GQA q->kv grouping (zero-padded heads produce zeros
    that are sliced off afterwards).  Two strategies, cheapest wins:
    (A) pad the per-kv-group fan-out G; (B) pad whole kv groups."""
    tp = axis_size("heads")
    H, KV = q.shape[2], k.shape[2]
    if tp <= 1 or (H % tp == 0 and H % KV == 0):
        return q, k, v, H
    G = H // KV

    def ceil_to(g, mod):
        while (g * mod) % tp:
            g += 1
        return g

    GA = ceil_to(G, KV)              # strategy A: H2 = KV * GA
    KVB = KV
    while (KVB * G) % tp:
        KVB += 1                     # strategy B: H2 = KVB * G
    if KV * GA <= KVB * G:           # pad fan-out within each kv group
        B_, S, _, D = q.shape
        qg = q.reshape(B_, S, KV, G, D)
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, GA - G), (0, 0)))
        return qg.reshape(B_, S, KV * GA, D), k, v, H
    # pad whole kv groups (adds zero kv heads and their zero q heads)
    q2 = jnp.pad(q, ((0, 0), (0, 0), (0, (KVB - KV) * G), (0, 0)))
    k2 = jnp.pad(k, ((0, 0), (0, 0), (0, KVB - KV), (0, 0)))
    v2 = jnp.pad(v, ((0, 0), (0, 0), (0, KVB - KV), (0, 0)))
    return q2, k2, v2, H


def run_attention(q, k, v, q_pos, k_pos, cfg: ModelConfig, *, causal=True,
                  window=0, impl="naive"):
    """Sharded full-sequence attention with TP head padding; returns
    (B,S,H,hd) with the ORIGINAL head count and grouping."""
    H, KV = q.shape[2], k.shape[2]
    q2, k2, v2, H_orig = pad_heads_for_tp(q, k, v)
    q2 = lshard(q2, "batch", "seq", "heads", "head_dim")
    k2 = lshard(k2, "batch", "seq", "kv_heads", "head_dim")
    v2 = lshard(v2, "batch", "seq", "kv_heads", "head_dim")
    out = attention_core(q2, k2, v2, q_pos, k_pos, impl=impl, causal=causal,
                         window=window, cap=cfg.attn_softcap)
    if out.shape[2] != H_orig:
        if k2.shape[2] == KV:                       # strategy A: regroup
            G2 = out.shape[2] // KV
            B_, S = out.shape[0], out.shape[1]
            out = out.reshape(B_, S, KV, G2, -1)[:, :, :, :H // KV, :]
            out = out.reshape(B_, S, H_orig, -1)
        else:                                       # strategy B: tail slice
            out = out[:, :, :H_orig, :]
    return out


def attention_layer(p, x, cfg: ModelConfig, *, positions, window=0,
                    impl="naive") -> jnp.ndarray:
    """Self-attention over the full (causal) sequence: (B,S,D)->(B,S,D)."""
    q, k, v = _proj_qkv(p, x, cfg, rope=True, positions=positions)
    out = run_attention(q, k, v, positions, positions, cfg, causal=True,
                        window=window, impl=impl)
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"].astype(x.dtype)


def cross_attention_layer(p, x, enc_kv, cfg: ModelConfig) -> jnp.ndarray:
    """Decoder->encoder attention; enc_kv = (k, v) precomputed from the
    encoder output: (B, F, KV, hd)."""
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"].astype(x.dtype), H, hd)
    k, v = enc_kv
    B, Sq = q.shape[0], q.shape[1]
    q_pos = jnp.zeros((B, Sq), jnp.int32)
    k_pos = jnp.zeros((B, k.shape[1]), jnp.int32)
    out = run_attention(q, k, v, q_pos, k_pos, cfg, causal=False,
                        impl="naive")
    out = out.reshape(*x.shape[:-1], -1)
    return out @ p["wo"].astype(x.dtype)


def decode_attention(p, x, cache_k, cache_v, pos, cfg: ModelConfig,
                     window=0):
    """Single-token decode: x (B,1,D), cache (B,Skv,KV,hd), pos (B,) int.

    Sliding-window layers use a RING-BUFFER cache of exactly ``window``
    slots (slot j holds the newest position p with p % window == j) —
    the cache read per step is O(window), not O(context).
    Returns (out (B,1,D), new_k, new_v)."""
    B = x.shape[0]
    S_slot = cache_k.shape[1]
    ring = bool(window) and S_slot == window
    q, k, v = _proj_qkv(p, x, cfg, rope=True,
                        positions=pos[:, None])
    write_pos = pos % S_slot if ring else pos
    cache_k = _cache_insert(cache_k, k, write_pos)
    cache_v = _cache_insert(cache_v, v, write_pos)
    cache_k = lshard(cache_k, "batch", "seq_kv", "kv_heads", "head_dim")
    cache_v = lshard(cache_v, "batch", "seq_kv", "kv_heads", "head_dim")
    slots = jnp.arange(S_slot, dtype=jnp.int32)[None, :]
    if ring:
        # logical position held by each slot, given the current pos
        k_pos = pos[:, None] - (pos[:, None] - slots) % S_slot
        valid = k_pos >= 0
    else:
        k_pos = jnp.broadcast_to(slots, (B, S_slot))
        valid = k_pos <= pos[:, None]
        if window:
            valid &= k_pos > pos[:, None] - window
    k_pos_masked = jnp.where(valid, k_pos, jnp.iinfo(jnp.int32).max)
    out = attention_core_naive(q, cache_k, cache_v, pos[:, None],
                               k_pos_masked, causal=True, window=0,
                               cap=cfg.attn_softcap)
    out = out.reshape(B, 1, -1)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


def _cache_insert(cache, new, pos):
    """cache (B,S,KV,hd), new (B,1,KV,hd), pos (B,) — scatter one row per
    batch element (in-place on a donated cache buffer)."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new[:, 0].astype(cache.dtype))


# ------------------------------------------------------------------ MLP / MoE
def mlp_params_layout(cfg: ModelConfig, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "w_gate": ((D, F), ("embed", "mlp"), D ** -0.5),
        "w_up": ((D, F), ("embed", "mlp"), D ** -0.5),
        "w_down": ((F, D), ("mlp", "embed"), F ** -0.5),
    }


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_layer(p, x, cfg: ModelConfig):
    h = _act(x @ p["w_gate"].astype(x.dtype), cfg.act) * \
        (x @ p["w_up"].astype(x.dtype))
    h = lshard(h, "batch", "seq", "mlp")
    return h @ p["w_down"].astype(x.dtype)


def moe_params_layout(cfg: ModelConfig):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "w_router": ((D, E), ("embed", None), D ** -0.5),
        "w_gate": ((E, D, F), ("experts", "embed", "expert_mlp"), D ** -0.5),
        "w_up": ((E, D, F), ("experts", "embed", "expert_mlp"), D ** -0.5),
        "w_down": ((E, F, D), ("experts", "expert_mlp", "embed"), F ** -0.5),
    }


def _dispatch_positions(expert_ids, n_experts):
    """expert_ids: (T,) int — position of each token within its expert's
    capacity buffer, computed by sort ranking (no T x E one-hot)."""
    T = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(T) - starts[sorted_e]
    pos = jnp.zeros(T, jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    return pos


def moe_layer(p, x, cfg: ModelConfig, capacity: Optional[int] = None):
    """Group-local scatter dispatch -> expert FFN (EP over 'experts') ->
    combine.  x: (B,S,D); groups = batch rows (data-parallel local).
    Returns (out, aux_loss)."""
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    C = capacity or max(1, min(S, int(math.ceil(S * K / E * cfg.capacity_factor))))

    logits = jnp.einsum("bsd,de->bse", x, p["w_router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_e = lax.top_k(probs, K)                      # (B,S,K)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=(0, 1))                            # (E,)
    ce = jnp.zeros(E).at[top_e.reshape(-1)].add(
        jnp.ones(top_e.size)) / max(top_e.size, 1)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- vectorized over groups (B = data-parallel-local batch rows) -----
    flat_e = top_e.reshape(B, S * K)
    pos = jax.vmap(lambda e: _dispatch_positions(e, E))(flat_e)  # (B,S*K)
    keepf = (pos < C) & (top_p.reshape(B, S * K) > 0)
    keep = keepf.astype(x.dtype)
    tok = jnp.repeat(jnp.arange(S), K)[None].repeat(B, 0)        # (B,S*K)

    xtok = jnp.take_along_axis(
        x, tok[..., None], axis=1)                               # (B,S*K,D)
    pos_c = jnp.clip(pos, 0, C - 1)
    buf = jnp.zeros((B, E, C, D), x.dtype)
    bidx = jnp.arange(B)[:, None].repeat(S * K, 1)
    buf = buf.at[bidx, flat_e, pos_c].add(xtok * keep[..., None])
    if cfg.moe_variant == "replicated_buf":
        # scatter stays model-rank-local; each rank computes only its
        # experts below (weights are expert-sharded), so the buffer is
        # never reshuffled across the 'model' axis.
        buf = lshard(buf, "batch", None, None, None)
    else:
        buf = lshard(buf, "batch", "experts", None, None)

    h = _act(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype)),
             cfg.act)
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    h = lshard(h, "batch", "experts", None, "expert_mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    if cfg.moe_variant == "replicated_buf":
        # one explicit all-gather of the (E,C,D) capacity buffer; the
        # token combine below then gathers from a REPLICATED buffer and
        # stays rank-local (otherwise XLA all-reduces full (B,S*K,D)
        # f32 tensors — see EXPERIMENTS.md §Perf cell C).
        out_buf = lshard(out_buf, "batch", None, None, None)
    else:
        out_buf = lshard(out_buf, "batch", "experts", None, None)

    # combine: gather each (token, k) slot's output, weight by router prob
    gathered = out_buf[bidx, flat_e, pos_c]                      # (B,S*K,D)
    if cfg.moe_variant == "replicated_buf":
        gathered = lshard(gathered, "batch", None, None)
    gathered = gathered * (keep * top_p.reshape(B, S * K).astype(x.dtype))[..., None]
    out = jnp.zeros((B, S, D), x.dtype).at[
        jnp.arange(B)[:, None].repeat(S * K, 1), tok].add(gathered)
    return out, aux
