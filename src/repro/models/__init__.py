"""Model substrate: unified transformer covering all assigned archs."""

from .config import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .transformer import (abstract_params, count_params, decode_step, forward,
                          init_cache, init_params, loss_fn,
                          model_flops_per_token, param_axes, param_layout,
                          prefill)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "param_layout", "init_params", "abstract_params", "param_axes",
           "count_params", "model_flops_per_token", "forward", "loss_fn",
           "prefill", "decode_step", "init_cache"]
