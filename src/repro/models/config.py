"""Model configuration for every assigned architecture family."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0                # 0 => d_model // n_heads

    # attention options
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sinusoidal_pos: bool = False     # whisper: absolute positions
    qkv_bias: bool = False
    attn_softcap: float = 0.0        # gemma2: 50.0
    logit_softcap: float = 0.0       # gemma2: 30.0
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma2: 2 (alternating local/global)
    embed_scale: bool = False        # gemma2: x * sqrt(d_model)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_period: int = 1              # jamba: 2 (every other layer)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "sharded_buf": scatter directly into the expert-sharded capacity
    # buffer (baseline; XLA may materialize cross-shard all-reduces).
    # "replicated_buf": scatter locally (buffer replicated over 'model'),
    # experts read their slice via the weight sharding — the §Perf
    # optimization for EP-heavy MoE (see EXPERIMENTS.md §Perf cell C).
    moe_variant: str = "sharded_buf"

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_head_dim: int = 64
    ssm_groups: int = 1

    # hybrid (jamba): layer kinds repeat with this period
    hybrid_period: int = 0           # jamba: 8
    hybrid_attn_index: int = 4       # position of the attention layer

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_frames: int = 1500             # stub frame-embedding frontend

    # VLM (pixtral): stub patch embeddings for the first n positions
    n_image_patches: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"

    # ------------------------------------------------------------- derived
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up to a 128 multiple so the
        vocab dim shards over any power-of-two TP extent (granite-moe's
        49155, whisper's 51865, mamba2's 50280 are not 16-divisible).
        Logits beyond vocab_size are masked to -inf (transformer._unembed)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:        # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:       # conv runs over [x, B, C]
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def scan_period(self) -> int:
        """Layers per scan body (stacked bodies = n_layers // period)."""
        if self.hybrid_period:
            return self.hybrid_period
        if self.local_global_period:
            return self.local_global_period
        return 1

    @property
    def n_bodies(self) -> int:
        assert self.n_layers % self.scan_period == 0, \
            f"{self.arch_id}: n_layers {self.n_layers} % period {self.scan_period}"
        return self.n_layers // self.scan_period

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for absolute layer index i."""
        if self.family == "ssm":
            return "ssm"
        if self.hybrid_period:
            return "attn" if i % self.hybrid_period == self.hybrid_attn_index \
                else "ssm"
        return "attn"

    def layer_window(self, i: int) -> int:
        """Sliding window for layer i (0 = full)."""
        if self.local_global_period:
            # even slots local (sliding window), odd slots global
            return self.sliding_window if i % self.local_global_period == 0 else 0
        return self.sliding_window

    def layer_is_moe(self, i: int) -> bool:
        if not self.n_experts:
            return False
        return i % self.moe_period == (self.moe_period - 1) \
            if self.moe_period > 1 else True

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when decode cost is sub-quadratic in context (SSM state or
        few-attention-layer hybrid)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --------------------------------------------------- analytic accounting
    def param_count(self) -> int:
        """Exact parameter count (matches init_params)."""
        from . import transformer  # lazy, avoids cycles
        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from . import transformer
        return transformer.count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason).  long_500k needs sub-quadratic decode."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention architecture: 512k-token KV decode is "
                       "quadratic-cost/KV-bound by construction (DESIGN.md "
                       "§Arch-applicability)")
    return True, ""
