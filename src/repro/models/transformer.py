"""Unified model: dense / MoE / hybrid(SSM+attn) / VLM / enc-dec / SSM.

One parameterized decoder (plus an optional encoder for whisper) covers
all ten assigned architectures.  Layers are stacked into scan *bodies*
of ``cfg.scan_period`` layer slots (1 for homogeneous stacks, 2 for
gemma2 local/global alternation, 8 for jamba's 1:7 attn:mamba pattern)
and iterated with ``lax.scan`` — one compiled body regardless of depth.

Params are plain nested dicts.  ``param_layout`` is the single source of
truth: every leaf is (shape, logical_axes, init_std), from which we
derive random init, abstract ShapeDtypeStructs (dry-run) and shardings.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..runtime.sharding import lshard
from .config import ModelConfig
from . import layers as L
from . import ssd as S

Layout = Dict[str, Any]           # nested: name -> (shape, axes, std) | dict
COMPUTE_DTYPE = jnp.bfloat16

# Dry-run probe hook (see layers.UNROLL_BLOCKS): unroll the layer scans so
# XLA cost_analysis counts every body exactly once per trip.
UNROLL_LAYERS = False


def _unroll(n: int) -> int:
    return n if UNROLL_LAYERS else 1


# ------------------------------------------------------------------ layout
def _slot_layout(cfg: ModelConfig, i: int, decoder: bool = True) -> Layout:
    """Layout of layer slot ``i`` (absolute index within a body)."""
    D = cfg.d_model
    slot: Layout = {"ln1": ((D,), ("embed",), 0.0)}
    if cfg.layer_kind(i) == "ssm":
        slot["ssm"] = S.ssd_params_layout(cfg)
    else:
        slot["attn"] = L.attn_params_layout(cfg)
    if cfg.is_encoder_decoder and decoder:
        slot["lnx"] = ((D,), ("embed",), 0.0)
        slot["xattn"] = L.attn_params_layout(cfg, cross=True)
    slot["ln2"] = ((D,), ("embed",), 0.0)
    if cfg.layer_is_moe(i):
        slot["moe"] = L.moe_params_layout(cfg)
    elif cfg.family == "ssm":
        pass                       # mamba2: no MLP, SSD block is the layer
    else:
        slot["mlp"] = L.mlp_params_layout(cfg)
    if cfg.family == "ssm":
        slot.pop("ln2", None)
    return slot


def param_layout(cfg: ModelConfig) -> Layout:
    D, V = cfg.d_model, cfg.padded_vocab
    out: Layout = {
        "embed": ((V, D), ("vocab", "embed"), D ** -0.5),
        "final_norm": ((D,), ("embed",), 0.0),
    }
    if not cfg.tie_embeddings:
        out["unembed"] = ((D, V), ("embed", "vocab"), D ** -0.5)
    body = {f"slot{i}": _slot_layout(cfg, i) for i in range(cfg.scan_period)}
    out["body"] = _stack_layout(body, cfg.n_bodies)
    if cfg.is_encoder_decoder:
        enc_body = {"slot0": {
            "ln1": ((D,), ("embed",), 0.0),
            "attn": L.attn_params_layout(cfg),
            "ln2": ((D,), ("embed",), 0.0),
            "mlp": L.mlp_params_layout(cfg),
        }}
        out["enc_body"] = _stack_layout(enc_body, cfg.n_encoder_layers)
        out["enc_norm"] = ((D,), ("embed",), 0.0)
    return out


def _stack_layout(layout: Layout, n: int) -> Layout:
    def stack(leaf):
        shape, axes, std = leaf
        return ((n, *shape), ("layers", *axes), std)
    return _map_leaves(layout, stack)


def _map_leaves(layout: Layout, f):
    if isinstance(layout, dict):
        return {k: _map_leaves(v, f) for k, v in layout.items()}
    return f(layout)


def _is_leaf(x):
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)


def init_params(cfg: ModelConfig, seed: int = 0,
                dtype=jnp.float32) -> Dict:
    layout = param_layout(cfg)

    def init(path, leaf):
        shape, axes, std = leaf
        key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                 abs(hash(path)) % (1 << 31))
        if std == 0.0:
            x = jnp.zeros(shape, dtype)
            if path.endswith("A_log"):
                x = jnp.broadcast_to(
                    jnp.log(jnp.linspace(1.0, 8.0, shape[-1], dtype=dtype)),
                    shape)
            if path.endswith("skip_D"):
                x = jnp.ones(shape, dtype)
            return x
        return jax.random.normal(key, shape, dtype) * std

    return _walk(layout, init, "")


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    return _map_leaves(param_layout(cfg),
                       lambda leaf: jax.ShapeDtypeStruct(leaf[0], dtype))


def param_axes(cfg: ModelConfig) -> Dict:
    return _map_leaves(param_layout(cfg), lambda leaf: leaf[1])


def _walk(layout, f, path):
    if isinstance(layout, dict):
        return {k: _walk(v, f, f"{path}/{k}") for k, v in layout.items()}
    return f(path, layout)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0

    def add(path, leaf):
        nonlocal total
        shape, axes, _ = leaf
        n = int(np.prod(shape))
        if active_only and "experts" in axes:
            n = int(n * cfg.top_k / max(cfg.n_experts, 1))
        total += n
        return None

    _walk(param_layout(cfg), add, "")
    return total


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS convention: 6·N (dense) / 6·N_active (MoE) per token."""
    return 6.0 * count_params(cfg, active_only=True)


# ----------------------------------------------------------------- forward
def _embed(params, cfg: ModelConfig, tokens, image_embeds=None, scale=None):
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), COMPUTE_DTYPE)
    if image_embeds is not None and cfg.n_image_patches:
        n = cfg.n_image_patches
        x = jnp.concatenate([image_embeds.astype(COMPUTE_DTYPE), x[:, n:]], 1)
    return x


def _unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        w = params["embed"].astype(COMPUTE_DTYPE)          # (V_pad, D)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = x @ params["unembed"].astype(COMPUTE_DTYPE)
    logits = logits.astype(jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:                 # mask pad rows
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return lshard(logits, "batch", "seq", "vocab")


def _slot_forward(slot_p, x, cfg: ModelConfig, i: int, positions,
                  enc_kv=None, impl="naive"):
    """One layer slot, full-sequence path.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, slot_p["ln1"], cfg.norm_eps)
    if cfg.layer_kind(i) == "ssm":
        x = x + S.ssd_layer(slot_p["ssm"], h, cfg)
        if cfg.family == "ssm":
            return x, aux
    else:
        x = x + L.attention_layer(slot_p["attn"], h, cfg, positions=positions,
                                  window=cfg.layer_window(i), impl=impl)
    if "xattn" in slot_p:
        hx = L.rms_norm(x, slot_p["lnx"], cfg.norm_eps)
        x = x + L.cross_attention_layer(slot_p["xattn"], hx, enc_kv, cfg)
    h2 = L.rms_norm(x, slot_p["ln2"], cfg.norm_eps)
    if "moe" in slot_p:
        out, a = L.moe_layer(slot_p["moe"], h2, cfg)
        x = x + out
        aux = aux + a
    else:
        x = x + L.mlp_layer(slot_p["mlp"], h2, cfg)
    return x, aux


def _body_scan(params_body, x, cfg: ModelConfig, positions, enc_kv=None,
               impl="naive", remat: bool = False, remat_policy=None):
    def body(carry, slot_params):
        x, aux = carry
        for i in range(cfg.scan_period):
            x, a = _slot_forward(slot_params[f"slot{i}"], x, cfg, i,
                                 positions, enc_kv=enc_kv, impl=impl)
            aux = aux + a
        x = lshard(x, "batch", "seq", None)
        return (x, aux), None

    if remat:
        body = jax.checkpoint(
            body, policy=remat_policy or
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    n = jax.tree.leaves(params_body)[0].shape[0]
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params_body,
                           unroll=_unroll(n))
    return x, aux


def _encode(params, cfg: ModelConfig, frames, impl="naive"):
    """Whisper encoder over stub frame embeddings (B,F,D)."""
    B, F, D = frames.shape
    x = frames.astype(COMPUTE_DTYPE) + \
        L.sinusoidal_positions(F, D)[None].astype(COMPUTE_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(carry, slot_params):
        x, _ = carry
        sp = slot_params["slot0"]
        h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
        # bidirectional self-attention, no rope
        q, k, v = L._proj_qkv(sp["attn"], h, cfg, rope=False,
                              positions=positions)
        o = L.run_attention(q, k, v, positions, positions, cfg,
                            causal=False, impl=impl)
        x = x + o.reshape(B, F, -1) @ sp["attn"]["wo"].astype(x.dtype)
        h2 = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.mlp_layer(sp["mlp"], h2, cfg)
        return (x, carry[1]), None

    n = jax.tree.leaves(params["enc_body"])[0].shape[0]
    (x, _), _ = lax.scan(body, (x, jnp.zeros(())), params["enc_body"],
                         unroll=_unroll(n))
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _enc_kv(params, cfg: ModelConfig, enc_out):
    """Precompute per-slot cross K/V from encoder output: stacked over
    bodies -> (n_bodies, B, F, KV, hd) each."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def per_body(slot_params):
        p = slot_params["slot0"]["xattn"]
        k = L._split_heads(enc_out @ p["wk"].astype(enc_out.dtype), KV, hd)
        v = L._split_heads(enc_out @ p["wv"].astype(enc_out.dtype), KV, hd)
        return k, v

    return jax.vmap(per_body, in_axes=0)(params["body"])


def forward(params, cfg: ModelConfig, tokens, *, frames=None,
            image_embeds=None, impl="naive", remat=False,
            remat_policy=None):
    """Full-sequence forward: tokens (B,S) -> (logits (B,S,V) f32, aux)."""
    B, Sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None],
                                 (B, Sq))
    x = _embed(params, cfg, tokens, image_embeds)
    if cfg.sinusoidal_pos:
        x = x + L.sinusoidal_positions(Sq, cfg.d_model)[None].astype(x.dtype)
    x = lshard(x, "batch", "seq", None)
    enc_kv = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, frames, impl=impl)
        enc_kv = _enc_kv(params, cfg, enc_out)
        # vmapped per-body kv: consumed inside the scan via xs
        x, aux = _body_scan_encdec(params, x, cfg, positions, enc_kv,
                                   impl=impl, remat=remat,
                                   remat_policy=remat_policy)
    else:
        x, aux = _body_scan(params["body"], x, cfg, positions, impl=impl,
                            remat=remat, remat_policy=remat_policy)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x), aux


def _body_scan_encdec(params, x, cfg, positions, enc_kv, impl, remat,
                      remat_policy=None):
    def body(carry, xs):
        x, aux = carry
        slot_params, kv = xs
        x, a = _slot_forward(slot_params["slot0"], x, cfg, 0, positions,
                             enc_kv=kv, impl=impl)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(
            body, policy=remat_policy or
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    n = jax.tree.leaves(params["body"])[0].shape[0]
    (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                           (params["body"], enc_kv), unroll=_unroll(n))
    return x, aux


# -------------------------------------------------------------------- loss
def loss_fn(params, cfg: ModelConfig, tokens, labels, **fw_kw):
    logits, aux = forward(params, cfg, tokens, **fw_kw)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    return loss + aux, (loss, aux)


# ----------------------------------------------------------- decode caches
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=COMPUTE_DTYPE, abstract: bool = False) -> Dict:
    """Stacked-over-bodies cache pytree for decode."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nb = cfg.n_bodies

    def arr(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    cache: Dict[str, Any] = {}
    for i in range(cfg.scan_period):
        if cfg.layer_kind(i) == "attn":
            w = cfg.layer_window(i)
            s_slot = min(max_seq, w) if w else max_seq  # ring buffer
            cache[f"slot{i}"] = {
                "k": arr((nb, batch, s_slot, KV, hd), dtype),
                "v": arr((nb, batch, s_slot, KV, hd), dtype)}
        else:
            cache[f"slot{i}"] = {
                "conv": arr((nb, batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
                "state": arr((nb, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)}
    if cfg.is_encoder_decoder:
        cache["cross"] = {
            "k": arr((nb, batch, cfg.n_frames, KV, hd), dtype),
            "v": arr((nb, batch, cfg.n_frames, KV, hd), dtype)}
    return cache


def cache_axes(cfg: ModelConfig) -> Dict:
    """Logical axes matching init_cache's structure."""
    axes: Dict[str, Any] = {}
    for i in range(cfg.scan_period):
        if cfg.layer_kind(i) == "attn":
            axes[f"slot{i}"] = {
                "k": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
                "v": ("layers", "batch", "seq_kv", "kv_heads", "head_dim")}
        else:
            axes[f"slot{i}"] = {
                "conv": ("layers", "batch", None, "ssm_inner"),
                "state": ("layers", "batch", "ssm_heads", None, "state")}
    if cfg.is_encoder_decoder:
        axes["cross"] = {
            "k": ("layers", "batch", "frames", "kv_heads", "head_dim"),
            "v": ("layers", "batch", "frames", "kv_heads", "head_dim")}
    return axes


def _slot_decode(slot_p, x, cfg: ModelConfig, i: int, slot_cache, pos,
                 cross_kv=None):
    new_cache = {}
    h = L.rms_norm(x, slot_p["ln1"], cfg.norm_eps)
    if cfg.layer_kind(i) == "ssm":
        out, new_cache = S.ssd_decode(slot_p["ssm"], h, slot_cache, cfg)
        x = x + out
        if cfg.family == "ssm":
            return x, new_cache
    else:
        out, ck, cv = L.decode_attention(slot_p["attn"], h, slot_cache["k"],
                                         slot_cache["v"], pos, cfg,
                                         window=cfg.layer_window(i))
        new_cache = {"k": ck, "v": cv}
        x = x + out
    if "xattn" in slot_p:
        hx = L.rms_norm(x, slot_p["lnx"], cfg.norm_eps)
        x = x + L.cross_attention_layer(slot_p["xattn"], hx, cross_kv, cfg)
    h2 = L.rms_norm(x, slot_p["ln2"], cfg.norm_eps)
    if "moe" in slot_p:
        out, _ = L.moe_layer(slot_p["moe"], h2, cfg)
        x = x + out
    else:
        x = x + L.mlp_layer(slot_p["mlp"], h2, cfg)
    return x, new_cache


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    """One decode step.  token (B,1) int32; pos (B,) int32 = position of
    this token.  Returns (logits (B,1,V) f32, new_cache)."""
    x = _embed(params, cfg, token)
    if cfg.sinusoidal_pos:
        pe_all = L.sinusoidal_positions(_max_pos(cfg, cache), cfg.d_model)
        x = x + pe_all[pos][:, None, :].astype(x.dtype)
    x = lshard(x, "batch", "seq", None)

    def body(carry, xs):
        x = carry
        if cfg.is_encoder_decoder:
            slot_params, slot_cache, cross_kv = xs
        else:
            slot_params, slot_cache = xs
            cross_kv = None
        new_cache = {}
        for i in range(cfg.scan_period):
            x, nc = _slot_decode(slot_params[f"slot{i}"], x, cfg, i,
                                 slot_cache[f"slot{i}"], pos,
                                 cross_kv=cross_kv)
            new_cache[f"slot{i}"] = nc
        return x, new_cache

    body_cache = {k: v for k, v in cache.items() if k != "cross"}
    if cfg.is_encoder_decoder:
        cross = (cache["cross"]["k"], cache["cross"]["v"])
        x, new_cache = lax.scan(body, x, (params["body"], body_cache, cross),
                                unroll=_unroll(cfg.n_bodies))
    else:
        x, new_cache = lax.scan(body, x, (params["body"], body_cache),
                                unroll=_unroll(cfg.n_bodies))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    if cfg.is_encoder_decoder:
        new_cache["cross"] = cache["cross"]
    return logits, new_cache


def _max_pos(cfg, cache):
    for slot in cache.values():
        if "k" in slot:
            return slot["k"].shape[2]
    return 4096


def prefill(params, cfg: ModelConfig, tokens, *, frames=None,
            image_embeds=None, max_seq: Optional[int] = None, impl="naive"):
    """Run the full prompt, return (logits_last (B,V), cache) with the KV
    cache sized to max_seq (>= prompt length)."""
    B, Sq = tokens.shape
    max_seq = max_seq or Sq
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None],
                                 (B, Sq))
    x = _embed(params, cfg, tokens, image_embeds)
    if cfg.sinusoidal_pos:
        x = x + L.sinusoidal_positions(Sq, cfg.d_model)[None].astype(x.dtype)
    x = lshard(x, "batch", "seq", None)
    enc_kv = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, frames, impl=impl)
        enc_kv = _enc_kv(params, cfg, enc_out)

    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    pad = max_seq - Sq

    def to_cache(k, v, window):
        """Lay k/v (B,Sq,KV,hd) out as this slot's decode cache: plain
        (padded to max_seq) for full attention; ring buffer of ``window``
        slots (slot = position %% window) for sliding-window layers."""
        w = min(max_seq, window) if window else 0
        if not w:
            kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            tail = min(Sq, w)
            slots = (jnp.arange(Sq - tail, Sq) % w).astype(jnp.int32)
            kp = jnp.zeros((B, w, KV, hd), k.dtype).at[:, slots].set(
                k[:, Sq - tail:])
            vp = jnp.zeros((B, w, KV, hd), v.dtype).at[:, slots].set(
                v[:, Sq - tail:])
        return (lshard(kp, "batch", "seq_kv", "kv_heads", "head_dim"),
                lshard(vp, "batch", "seq_kv", "kv_heads", "head_dim"))

    def body(carry, xs):
        x = carry
        slot_params = xs if enc_kv is None else xs[0]
        kvx = None if enc_kv is None else (xs[1][0], xs[1][1])
        new_cache = {}
        for i in range(cfg.scan_period):
            sp = slot_params[f"slot{i}"]
            h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
            if cfg.layer_kind(i) == "ssm":
                out, sc = S.ssd_layer(sp["ssm"], h, cfg, return_cache=True)
                x = x + out
                new_cache[f"slot{i}"] = sc
                if cfg.family == "ssm":
                    continue
            else:
                q, k, v = L._proj_qkv(sp["attn"], h, cfg, rope=True,
                                      positions=positions)
                o = L.run_attention(q, k, v, positions, positions, cfg,
                                    causal=True,
                                    window=cfg.layer_window(i), impl=impl)
                x = x + o.reshape(B, Sq, -1) @ sp["attn"]["wo"].astype(x.dtype)
                kp, vp = to_cache(k, v, cfg.layer_window(i))
                new_cache[f"slot{i}"] = {"k": kp, "v": vp}
            if "xattn" in sp:
                hx = L.rms_norm(x, sp["lnx"], cfg.norm_eps)
                x = x + L.cross_attention_layer(sp["xattn"], hx, kvx, cfg)
            h2 = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
            if "moe" in sp:
                out, _ = L.moe_layer(sp["moe"], h2, cfg)
                x = x + out
            elif "mlp" in sp:
                x = x + L.mlp_layer(sp["mlp"], h2, cfg)
        x = lshard(x, "batch", "seq", None)
        return x, new_cache

    if cfg.is_encoder_decoder:
        x, cache = lax.scan(body, x, (params["body"], enc_kv),
                            unroll=_unroll(cfg.n_bodies))
        cache["cross"] = {"k": enc_kv[0], "v": enc_kv[1]}
    else:
        x, cache = lax.scan(body, x, params["body"],
                            unroll=_unroll(cfg.n_bodies))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:, :])
    return logits[:, 0, :], cache
