"""Pallas TPU kernels for the perf-critical compute hot spots.

The paper itself (LCAP) is host-side and has no numeric kernel; the
kernels here serve the framework substrate the assignment requires:
flash_attention — blockwise attention with causal/sliding-window/
softcap/GQA, the dominant FLOP sink of every attention architecture in
the assignment.  Validated in interpret mode against ref.py on CPU; the
BlockSpec tiling targets TPU VMEM/MXU.
"""

from . import ops, ref
from .ops import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
