"""Flash attention Pallas TPU kernel.

TPU-native blockwise attention: KV streamed HBM->VMEM block by block,
running (max, denom, accumulator) kept in VMEM scratch across the
innermost grid dimension, MXU-aligned (block and head dims padded to
multiples of 128 by the ops.py wrapper).  Supports causal masking,
sliding window, logit softcap (gemma2) and GQA (the kv BlockSpec index
map folds q-head -> kv-head).

Grid: (batch*q_heads, n_q_blocks, n_kv_blocks); the kv dimension is
"arbitrary" (sequential) so scratch persists across it.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the pinned JAX names this TPUCompilerParams; newer releases renamed it
# to CompilerParams — accept either
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or \
    getattr(pltpu, "CompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, cap: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k: int, seq_q: int,
                  seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = (k_pos < seq_k) & (q_pos < seq_q)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                 # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    # rows that are fully masked keep p==exp(NEG_INF-...)->0 via the guard:
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, cap=0.0,
                         scale=None, block_q=512, block_k=512,
                         seq_q=None, seq_k=None, interpret=True):
    """q: (BH, Sq, D); k, v: (BKV, Sk, D) with BH = B*H, BKV = B*KV.
    Sq/Sk/D must already be padded to block/lane multiples by the caller;
    ``seq_q``/``seq_k`` give the pre-padding logical lengths.
    Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV  # q heads per kv head, per batch handled in index map
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q = Sq // block_q
    n_k = Sk // block_k
    seq_q = seq_q or Sq
    seq_k = seq_k or Sk

    kernel = functools.partial(
        _flash_kernel, scale=scale, cap=cap, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_q=seq_q, seq_k=seq_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
