"""Changelog-stream routing kernels (JAX / Pallas path).

The cluster's routing hot spot is a splitmix64 mix over three decoded
FID header columns (``cluster.fid_slots``).  NumPy computes it with
native wrapping uint64 arithmetic; this module provides the *identical*
mix as a jitted JAX kernel for deployments that keep the routing
columns on an accelerator (the coordinator co-located with the
training job's host program).

JAX disables 64-bit integers unless ``jax_enable_x64`` is set — which
the training side must not flip globally — so the mix runs on
``(hi, lo)`` uint32 *pairs*: 64-bit multiplies are composed from
16x16->32 partial products, shifts and xors act lane-wise on the pair.
Only the low 64 bits of each product are needed, which keeps the limb
algebra to one full 32x32 product plus two wrapping cross terms.

``fid_slots`` is the host-callable wrapper (numpy in, numpy out).
``fid_slots_pallas`` routes the same mix through a ``pallas_call``
elementwise kernel (VMEM-resident, interpret mode off-TPU) — the
fusion-friendly form for TPU deployments.  Both are gated behind
``REPRO_JAX_ROUTING=1`` in ``cluster.batch_slots``; the numpy path
stays the production default on CPU hosts.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

_C1 = 0xBF58476D1CE4E5B9
_C2 = 0x94D049BB133111EB
_MIX = 0x9E3779B97F4A7C15          # splitmix64 increment (golden ratio)

_LO16 = np.uint32(0xFFFF)  # numpy scalar: weak constant inside pallas kernels
_MAX_SLOTS = 1 << 16               # keeps the modulus inside uint32


def _split(c):
    return np.uint32(c >> 32), np.uint32(c & 0xFFFFFFFF)


def _mul32(a, b):
    """Full 32x32->64 product of two uint32 lanes, as a (hi, lo) pair."""
    a0, a1 = a & _LO16, a >> 16
    b0, b1 = b & _LO16, b >> 16
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    mid = (p00 >> 16) + (p01 & _LO16) + (p10 & _LO16)
    lo = (p00 & _LO16) | (mid << 16)
    hi = a1 * b1 + (p01 >> 16) + (p10 >> 16) + (mid >> 16)
    return hi, lo


def _mul64(zh, zl, ch, cl):
    """Low 64 bits of ``(zh:zl) * (ch:cl)``, as a (hi, lo) pair: the
    cross terms only touch the high lane, wrapping in uint32."""
    hi, lo = _mul32(zl, cl)
    return hi + zl * ch + zh * cl, lo


def _shr64(zh, zl, k):
    """``(zh:zl) >> k`` for 0 < k < 32."""
    return zh >> k, (zl >> k) | (zh << (32 - k))


def _mix64(zh, zl, n_slots):
    """The splitmix64 finalizer + slot modulus on uint32 pairs."""
    for k, c in ((30, _C1), (27, _C2)):
        sh, sl = _shr64(zh, zl, k)
        zh, zl = zh ^ sh, zl ^ sl
        zh, zl = _mul64(zh, zl, *_split(c))
    sh, sl = _shr64(zh, zl, 31)
    zh, zl = zh ^ sh, zl ^ sl
    n = np.uint32(n_slots)
    # (hi:lo) mod n == (hi mod n) * (2^32 mod n) + (lo mod n), all of
    # which stay below 2^32 while n_slots < 2^16
    return ((zh % n) * np.uint32((1 << 32) % n_slots) + zl % n) % n


def _seed64(seq_hi, seq_lo, oid, ver):
    """seq*C1 ^ oid*C2 ^ ver*MIX on uint32 pairs."""
    zero = jnp.zeros_like(oid)
    zh, zl = _mul64(seq_hi, seq_lo, *_split(_C1))
    th, tl = _mul64(zero, oid, *_split(_C2))
    zh, zl = zh ^ th, zl ^ tl
    th, tl = _mul64(zero, ver, *_split(_MIX))
    return zh ^ th, zl ^ tl


@functools.partial(jax.jit, static_argnames=("n_slots",))
def _fid_slots_jit(seq_hi, seq_lo, oid, ver, n_slots):
    zh, zl = _seed64(seq_hi, seq_lo, oid, ver)
    return _mix64(zh, zl, n_slots)


def _as_pairs(seq, oid, ver):
    seq = np.ascontiguousarray(seq, dtype=np.uint64)
    return ((seq >> np.uint64(32)).astype(np.uint32),
            seq.astype(np.uint32),
            np.ascontiguousarray(oid, dtype=np.uint32),
            np.ascontiguousarray(ver, dtype=np.uint32))


def fid_slots(seq, oid, ver, n_slots: int = 64) -> np.ndarray:
    """JAX twin of ``cluster.fid_slots``: same columns in, same slots
    out (int64 numpy array)."""
    if not 0 < n_slots < _MAX_SLOTS:
        raise ValueError(f"n_slots must be in (0, {_MAX_SLOTS})")
    out = _fid_slots_jit(*_as_pairs(seq, oid, ver), n_slots=int(n_slots))
    return np.asarray(out).astype(np.int64)


# -- Pallas form -----------------------------------------------------------
def _slots_kernel(seq_hi_ref, seq_lo_ref, oid_ref, ver_ref, out_ref,
                  *, n_slots):
    zh, zl = _seed64(seq_hi_ref[:], seq_lo_ref[:], oid_ref[:], ver_ref[:])
    out_ref[:] = _mix64(zh, zl, n_slots)


def fid_slots_pallas(seq, oid, ver, n_slots: int = 64,
                     interpret: bool = True) -> np.ndarray:
    """The same mix as one elementwise ``pallas_call`` (VMEM in/out).

    Interpret mode (the off-TPU default) runs the kernel body in
    Python — used by the equivalence tests; on TPU the kernel is a
    single VPU pass over the routing columns."""
    from jax.experimental import pallas as pl

    if not 0 < n_slots < _MAX_SLOTS:
        raise ValueError(f"n_slots must be in (0, {_MAX_SLOTS})")
    seq_hi, seq_lo, oid, ver = _as_pairs(seq, oid, ver)
    out = pl.pallas_call(
        functools.partial(_slots_kernel, n_slots=int(n_slots)),
        out_shape=jax.ShapeDtypeStruct(seq_lo.shape, jnp.uint32),
        interpret=interpret,
    )(seq_hi, seq_lo, oid, ver)
    return np.asarray(out).astype(np.int64)
