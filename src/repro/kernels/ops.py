"""Jitted wrapper around the flash attention Pallas kernel.

Handles: GQA head folding, padding of sequence lengths to block
multiples and head_dim to the 128-lane MXU width, and the
models.layers-compatible calling convention.  ``interpret=True``
(default off-TPU) runs the kernel body in Python for validation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover
        return False


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "cap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal=True,
                    window=0, cap=0.0, scale=None, block_q=512,
                    block_k=512, interpret=None, **_ignored):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D) -> (B,Sq,H,D).

    Positions are assumed contiguous from 0 (training/prefill layout);
    the q_pos/k_pos arguments exist for signature compatibility with
    ``models.layers.attention_core``."""
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D ** -0.5

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pd = (-D) % 128
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, pd)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, pd)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, pd)))

    qf = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq + pq, D + pd)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, D + pd)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, D + pd)

    of = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                              cap=cap, scale=scale, block_q=block_q,
                              block_k=block_k, seq_q=Sq, seq_k=Sk,
                              interpret=interpret)
    o = of.reshape(B, H, Sq + pq, D + pd).transpose(0, 2, 1, 3)
    return o[:, :Sq, :, :D]
