"""Pure-jnp oracle for the flash attention kernel (independent of
models.layers; deliberately the simplest possible formulation)."""

from __future__ import annotations

import jax.numpy as jnp
import jax


def attention_reference(q, k, v, *, causal=True, window=0, cap=0.0,
                        scale=None):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D).  Returns (B,Sq,H,D) in q.dtype."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    kh = jnp.repeat(k, G, axis=2)                       # (B,Sk,H,D)
    vh = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return o.astype(q.dtype)
